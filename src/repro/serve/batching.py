"""Continuous-batching serve engine: one compiled decode step, churning
requests expressed entirely as per-slot *data*.

The paper's technique is a per-step, per-row vocab-sized categorical draw
— the decode inner loop of an LLM serving stack.  This module grows the
single-step factories of :mod:`repro.serve.engine` into a request
lifecycle around that draw, holding one invariant above all others: the
decode step is traced and compiled **exactly once**, and nothing a user
can submit — prompt length, token budget, temperature, top-k/p, min-p,
seed, arrival order, queue churn — changes its shape.  The analogue of
WarpLDA/EZLDA's "fix the hot kernel, restructure the scheduling around
it", applied to serving:

* **Fixed decode batch.**  ``max_slots`` rows, always.  A request is a
  *slot assignment*; EOS / length-exhausted slots are released and
  refilled from the bounded waiting queue between steps (FCFS,
  :mod:`repro.serve.scheduler`), their KV rows reset in place by the
  insert step.
* **Per-slot positions.**  Every slot decodes at its own sequence length
  — ``cache_pos`` is a (B,) traced vector, threaded down through
  ``lm_decode`` / ``gqa_attend`` / ``mla_attend_decode`` (per-row RoPE
  angles, per-row one-hot cache writes, per-row prefix masks), so
  sequences of wildly different lengths share one step.
* **Per-slot sampling params as traced leaves.**  temperature / top-k /
  top-p / min-p ride in as (B,) / (B, 3) float operands; truncation is
  the butterfly-native per-row threshold (``repro.sampling.transforms``),
  so a heterogeneous batch (each request its own nucleus) is served by
  the same executable as a homogeneous one.
* **Per-slot counter-RNG streams.**  The uniform drawing request r's t-th
  token is ``threefry(seed_r, t)`` (``repro.kernels.rng``) — a pure
  function of the *request*, not the slot, the batch, or the step count.
  Slot recycling therefore cannot perturb any live stream, dead slots
  draw from their own stale streams into discarded outputs, and a
  request's tokens are bit-identical to a one-at-a-time run with the same
  seed (the recycling invariant ``tests/test_serve_engine`` pins).
* **Prefill/decode interleaving.**  Prompts prefill one request at a
  time into pow2-bucketed lengths (a handful of traces, counted
  separately), at most ``prefill_chunk`` per decode step so admission
  never starves the running batch.
* **Sharded decode composes.**  ``mesh=`` row-shards the draw through
  the same shard_map'd per-shard build+draw the PR 4 sampler uses; the
  per-slot uniforms shard with their rows, so tokens stay bit-identical
  for any device count.

Zero-retrace is *measured*, not asserted by construction:
:meth:`ContinuousBatchingEngine.compile_stats` exposes the decode step's
jit cache size and ``sampling.plan_stats()``, and the churn test +
``benchmarks/serve_bench.py`` gate them.
"""

from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro import sampling
from repro.kernels import rng as _rng
from repro.models.model import Model
from repro.models.params import init_params
from repro.sampling import distribution as _dist
from repro.sampling import sharded as _sharded
from repro.sampling import transforms as _tr
from repro.serve.request import FinishReason, Request, RequestState
from repro.serve.scheduler import QueueFullError, Scheduler

__all__ = ["ContinuousBatchingEngine", "QueueFullError"]

# cache leaves with a (L, B, S, ...) sequence axis (axis 2 when stacked);
# everything else (SSM conv/state) is per-row state without one
_SEQ_LEAF_NAMES = frozenset({"k", "v", "c_kv", "k_pe"})

# kpm block of a request that does not truncate: top_k=0, top_p=1, min_p=0
_KPM_OFF = np.array([0.0, 1.0, 0.0], np.float32)


def _bucket(n: int) -> int:
    """Smallest power of two >= n (prefill length buckets: bounded trace
    count, log2(max_len) distinct prefill shapes)."""
    return 1 << max(0, int(n - 1).bit_length())


class ContinuousBatchingEngine:
    """Asyncio serve engine over a fixed, slot-recycled decode batch.

    Synchronous core (``submit_nowait`` / ``run``) for tests and batch
    jobs; asyncio surface (``start`` / ``submit`` / ``drain`` / ``stop``)
    for open-loop serving (``benchmarks/serve_bench.py``).
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        max_slots: Optional[int] = None,
        max_len: Optional[int] = None,
        max_waiting: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        temperature: float = 1.0,
        eos_id: Optional[int] = None,
        mesh=None,
        cache_dtype=jnp.float32,
    ):
        cfg = model.cfg
        if cfg.encoder_layers > 0 or cfg.frontend_len > 0 or cfg.meta_tokens > 0:
            raise ValueError(
                "continuous batching serves plain decoder-only families; "
                f"config {cfg.name!r} has encoder/frontend/meta-token "
                "prefixes whose slot layout is not implemented"
            )
        serve = cfg.serve_spec
        self.model = model
        self.params = params
        self.max_slots = int(max_slots or serve.max_slots)
        self.max_len = int(max_len or serve.max_len)
        self.prefill_chunk = (
            serve.prefill_chunk if prefill_chunk is None else prefill_chunk
        )
        self.temperature = float(temperature)
        self.eos_id = eos_id
        self.mesh = mesh
        self.scheduler = Scheduler(
            self.max_slots,
            serve.max_waiting if max_waiting is None else max_waiting,
        )

        B, V = self.max_slots, cfg.padded_vocab
        self._plan, self._local_plan = self._resolve_plans(B, V)

        # the decode cache: (L, B, S, ...) leaves, zero-initialized once;
        # slot rows are reset in place on every admit
        self._caches = init_params(
            jax.random.PRNGKey(0), model.cache_specs(B, self.max_len),
            cache_dtype,
        )
        self._empty_prefix = init_params(
            jax.random.PRNGKey(0), model.cache_specs(1, 1), cache_dtype
        )

        # per-slot host state, device-fed each step (fixed shapes)
        self._token = np.zeros((B,), np.int32)
        self._pos = np.zeros((B,), np.int32)
        self._seeds = np.zeros((B, 2), np.uint32)
        self._draw_idx = np.zeros((B,), np.uint32)
        self._temp = np.ones((B,), np.float32)
        self._kpm = np.tile(_KPM_OFF, (B, 1))
        self._active = np.zeros((B,), bool)

        self._step = self._build_decode_step()
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, {"tokens": toks})[1]
        )
        self._insert = jax.jit(self._insert_impl)
        self._seed_pair = jax.jit(
            lambda s: _rng.fold(
                _rng.seed_from_key(jax.random.PRNGKey(s)), _rng.TAG_U
            )
        )

        # metrics
        self.step_times: List[Dict] = []     # {"dt": s, "active": n, "tokens": n}
        self.prefill_times: List[Dict] = []  # {"dt": s, "bucket": n}
        self._steps = 0
        self._tokens_out = 0

        # asyncio surface
        self._running = False
        self._loop_task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None

    # -- planning ----------------------------------------------------------

    def _resolve_plans(self, B: int, V: int):
        """A u-driven sampler plan for the (B, V) decode workload.

        The per-slot RNG streams hand the draw an explicit (B,) uniform
        vector, so key-driven variants (gumbel / alias) can't serve here;
        autotune resolutions landing on one fall back to butterfly."""
        spec = self.model.cfg.sampler_spec

        def uplan(shape, devices=1):
            p = sampling.plan(
                shape, method=spec.method, W=spec.W or None, dtype="float32",
                draws=1, has_key=False, devices=devices,
            )
            if p.method in _dist.KEY_VARIANTS or (
                p.table_method in _dist.FACTORED_VARIANTS
            ):
                p = sampling.plan(
                    shape, method="butterfly", W=spec.W or None,
                    dtype="float32", draws=1, has_key=False, devices=devices,
                )
            return p

        if self.mesh is None:
            return uplan((B, V)), None
        nd = _sharded.data_size(self.mesh)
        if B % nd:
            raise ValueError(
                f"max_slots={B} must divide over the mesh's {nd} data "
                "shards"
            )
        return None, uplan((B // nd, V), devices=nd)

    # -- compiled pieces ---------------------------------------------------

    def _build_decode_step(self):
        model, mesh = self.model, self.mesh
        plan, local_plan = self._plan, self._local_plan

        def draw(w, u, kpm):
            if mesh is None and plan.method in ("kernel", "kernel_trunc"):
                # ONE fused kernel: threshold bisection + walk in-tile
                from repro.kernels.butterfly_sample import ops as _kops

                return _kops.butterfly_sample_truncated(
                    w, u, kpm, W=plan.W, tb=plan.tb or 8, tk=plan.tk or 512
                )
            tau = _tr.thresholds_from_params(w, kpm)
            wm = jnp.where(w >= tau[:, None], w, jnp.zeros_like(w))
            if mesh is None:
                return _dist.draw(plan.build(wm), u=u)
            rs = _sharded.row_spec(mesh)

            def local(wm_l, u_l):
                return _dist.draw(local_plan.build(wm_l), u=u_l)

            return _shard_map(
                local, mesh=mesh,
                in_specs=(P(rs[0], None), rs), out_specs=rs,
                check_rep=False,  # pallas_call has no replication rule
            )(wm, u)

        @jax.jit
        def step(params, caches, token, pos, seeds, draw_idx, temp, kpm):
            logits, caches = model.decode(params, caches, token[:, None], pos)
            # per-slot stream: uniform for (request seed, token index) —
            # independent of slot id, batch mix, and device count
            bits, _ = _rng.threefry2x32(
                seeds[:, 0], seeds[:, 1], draw_idx, jnp.zeros_like(draw_idx)
            )
            u = _rng.bits_to_uniform(bits)
            safe_t = jnp.where(temp > 0, temp, jnp.ones_like(temp))
            w = _dist.logits_to_weights(logits, safe_t).astype(jnp.float32)
            sampled = draw(w, u, kpm).astype(jnp.int32)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jnp.where(temp > 0, sampled, greedy), caches

        return step

    @staticmethod
    def _insert_impl(caches, prefix, slot):
        """Write one request's prefilled prefix into a slot — and reset
        the slot's remaining rows in place (the zero pad), so no KV from
        the slot's previous occupant survives recycling."""

        def upd(path, big, small):
            names = {getattr(k, "key", None) for k in path}
            if names & _SEQ_LEAF_NAMES:
                pad = [(0, 0)] * small.ndim
                pad[2] = (0, big.shape[2] - small.shape[2])
                small = jnp.pad(small, pad)
            start = (0, slot) + (0,) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), start
            )

        return jax.tree_util.tree_map_with_path(upd, caches, prefix)

    # -- submission --------------------------------------------------------

    def submit_nowait(self, req: Request) -> Request:
        """Admit a request (synchronous).  Raises ``ValueError`` when the
        request can't fit a slot's KV budget, :class:`QueueFullError`
        when admission control rejects it."""
        if req.total_budget > self.max_len:
            req.state = RequestState.REJECTED
            req.finish_reason = FinishReason.REJECTED
            raise ValueError(
                f"request needs {req.total_budget} KV positions "
                f"(prompt {req.prompt_len} + max_new {req.max_new_tokens}) "
                f"> engine max_len {self.max_len}"
            )
        if req.arrival_time < 0:
            req.arrival_time = time.perf_counter()
        try:
            return self.scheduler.submit(req)
        except QueueFullError:
            req.finish_reason = FinishReason.REJECTED
            if req.future is not None and not req.future.done():
                req.future.set_result(req)
            raise

    async def submit(self, req: Request) -> Request:
        """Asyncio admission: attaches a future resolved at finish."""
        loop = asyncio.get_running_loop()
        req.future = loop.create_future()
        self.submit_nowait(req)
        if self._wake is not None:
            self._wake.set()
        return req

    # -- the scheduling loop ------------------------------------------------

    def _admit(self) -> int:
        """Refill free slots from the queue head; at most ``prefill_chunk``
        prefills per call (0 = no cap) so decode latency stays bounded."""
        admitted = 0
        budget = self.prefill_chunk or self.max_slots
        for slot in self.scheduler.free_slots():
            if admitted >= budget:
                break
            req = self.scheduler.next_waiting()
            if req is None:
                break
            self._prefill_into(slot, req)
            self.scheduler.bind(slot, req)
            admitted += 1
        return admitted

    def _prefill_into(self, slot: int, req: Request) -> None:
        req.state = RequestState.PREFILLING
        t0 = time.perf_counter()
        prefix = req.prompt[:-1]
        if prefix.size:
            sb = _bucket(prefix.size)
            toks = np.zeros((1, sb), np.int32)
            toks[0, : prefix.size] = prefix
            pre = self._prefill(self.params, jnp.asarray(toks))
        else:
            # single-token prompt: no prefix — the insert still resets
            # the slot's rows with the zero-length (all-pad) prefix
            sb = 0
            pre = self._empty_prefix
        self._caches = self._insert(self._caches, pre, jnp.int32(slot))
        req.prefill_time = time.perf_counter()
        self.prefill_times.append({"dt": req.prefill_time - t0, "bucket": sb})
        # slot state: the prompt's LAST token runs through the decode step
        # at position prompt_len-1 (writes its own KV, yields the first
        # sampled token) — prefill logits are never consumed
        self._token[slot] = int(req.prompt[-1])
        self._pos[slot] = req.prompt_len - 1
        self._seeds[slot] = np.asarray(self._seed_pair(np.uint32(req.seed)))
        self._draw_idx[slot] = 0
        sp = req.sampling
        self._temp[slot] = req.effective_temperature(self.temperature)
        self._kpm[slot] = (
            float(sp.top_k or 0),
            float(1.0 if sp.top_p is None else sp.top_p),
            float(sp.min_p or 0.0),
        )
        self._active[slot] = True

    def step_once(self) -> int:
        """One batched decode step over every slot.  Returns the number of
        live tokens produced (0 when no slot is active)."""
        if not self._active.any():
            return 0
        t0 = time.perf_counter()
        nxt, self._caches = self._step(
            self.params, self._caches,
            jnp.asarray(self._token), jnp.asarray(self._pos),
            jnp.asarray(self._seeds), jnp.asarray(self._draw_idx),
            jnp.asarray(self._temp), jnp.asarray(self._kpm),
        )
        nxt_np = np.asarray(nxt)  # host sync: the step's wall-clock edge
        now = time.perf_counter()
        live = int(self._active.sum())
        self.step_times.append(
            {"dt": now - t0, "active": live, "tokens": live}
        )
        self._steps += 1
        self._tokens_out += live
        for slot in np.nonzero(self._active)[0]:
            req = self.scheduler.bound(int(slot))
            tok = int(nxt_np[slot])
            if not req.output_tokens:
                req.first_token_time = now
            req.output_tokens.append(tok)
            req.token_times.append(now)
            self._token[slot] = tok
            self._pos[slot] += 1
            self._draw_idx[slot] += 1
            eos = req.eos_id if req.eos_id is not None else self.eos_id
            if eos is not None and tok == eos:
                self._finish(int(slot), FinishReason.EOS)
            elif len(req.output_tokens) >= req.max_new_tokens:
                self._finish(int(slot), FinishReason.LENGTH)
        return live

    def _finish(self, slot: int, reason: FinishReason) -> None:
        req = self.scheduler.release(slot)
        req.state = RequestState.FINISHED
        req.finish_reason = reason
        req.finish_time = time.perf_counter()
        self._active[slot] = False
        self._token[slot] = 0
        self._pos[slot] = 0
        self._draw_idx[slot] = 0
        self._temp[slot] = 1.0
        self._kpm[slot] = _KPM_OFF
        if req.future is not None and not req.future.done():
            req.future.set_result(req)

    def run(self, requests: Sequence[Request] = ()) -> List[Request]:
        """Synchronous drain: submit, then interleave admission and decode
        steps until queue and slots are empty."""
        out = []
        for r in requests:
            out.append(self.submit_nowait(r))
        while not self.scheduler.idle:
            self._admit()
            self.step_once()
        return out

    # -- asyncio surface ---------------------------------------------------

    async def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._wake = asyncio.Event()
        self._loop_task = asyncio.create_task(self._serve_loop())

    async def stop(self) -> None:
        self._running = False
        if self._wake is not None:
            self._wake.set()
        if self._loop_task is not None:
            await self._loop_task
            self._loop_task = None

    async def drain(self) -> None:
        """Wait until every admitted request has finished."""
        while not self.scheduler.idle:
            await asyncio.sleep(0.001)

    async def _serve_loop(self) -> None:
        while self._running:
            if self.scheduler.idle:
                self._wake.clear()
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout=0.02)
                except asyncio.TimeoutError:
                    pass
                continue
            self._admit()
            self.step_once()
            # the step blocks this coroutine; yield so submissions whose
            # arrival times passed during it get admitted next iteration
            await asyncio.sleep(0)

    # -- introspection ------------------------------------------------------

    def warmup(self, max_prompt_len: int = 16, max_new_tokens: int = 2) -> None:
        """Trace everything a later run will touch: the decode step and
        each pow2 prefill bucket up to ``max_prompt_len``.  Metrics are
        reset after, so a post-warmup ``compile_stats()`` snapshot makes
        'zero retraces under churn' a checkable assertion."""
        lens, n = [], 1
        while n < max(1, max_prompt_len - 1):
            lens.append(n + 1)  # prefix of length n -> bucket n
            n *= 2
        lens.append(max(1, max_prompt_len))
        self.run([
            Request(
                prompt=np.zeros((ln,), np.int32),
                max_new_tokens=max_new_tokens,
                seed=i,
            )
            for i, ln in enumerate(lens)
        ])
        self.reset_metrics()

    def reset_metrics(self) -> None:
        self.step_times.clear()
        self.prefill_times.clear()
        self._steps = 0
        self._tokens_out = 0

    def compile_stats(self) -> Dict[str, int]:
        """Trace/compile counters for the zero-retrace gate: after warmup
        ``decode_step_compiles`` must stay at 1 no matter what churns."""
        return {
            "decode_step_compiles": int(self._step._cache_size()),
            "prefill_compiles": int(self._prefill._cache_size()),
            "insert_compiles": int(self._insert._cache_size()),
            "plan_stats": sampling.plan_stats(),
        }

    def stats(self) -> Dict:
        sched = self.scheduler.stats
        return {
            **sched,
            "steps": self._steps,
            "tokens_out": self._tokens_out,
            "waiting": self.scheduler.waiting_depth,
            "active": self.scheduler.active_slots,
            "max_slots": self.max_slots,
        }
