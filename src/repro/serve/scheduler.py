"""Admission control + slot scheduling for continuous batching.

The scheduler owns the two resources of the serving system: a bounded
waiting queue (admission control — beyond ``max_waiting`` a submission is
*rejected*, never silently dropped or unboundedly buffered) and the
``max_slots`` decode slots of the fixed-shape batch.  Policy is FCFS:
freed slots are refilled from the queue head between decode steps, which
is exactly the WarpLDA/EZLDA restructuring argument applied to serving —
the hot kernel (one compiled decode step) never changes shape; all churn
lives in this layer as data.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

from repro.serve.request import Request, RequestState

__all__ = ["QueueFullError", "Scheduler"]


class QueueFullError(RuntimeError):
    """Admission control: the waiting queue is at ``max_waiting``."""


class Scheduler:
    def __init__(self, max_slots: int, max_waiting: int):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if max_waiting < 0:
            raise ValueError(f"max_waiting must be >= 0, got {max_waiting}")
        self.max_slots = max_slots
        self.max_waiting = max_waiting
        self._waiting: Deque[Request] = deque()
        self._slots: List[Optional[Request]] = [None] * max_slots
        self._next_id = 0
        self.stats: Dict[str, int] = {
            "submitted": 0,
            "rejected": 0,
            "finished": 0,
        }

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request) -> Request:
        """Admit a request into the waiting queue, or reject it.

        Raises :class:`QueueFullError` when the queue holds
        ``max_waiting`` requests already (the request is marked REJECTED
        so a caller holding a handle sees a terminal state)."""
        if len(self._waiting) >= self.max_waiting:
            self.stats["rejected"] += 1
            req.state = RequestState.REJECTED
            raise QueueFullError(
                f"waiting queue full ({self.max_waiting}); request rejected"
            )
        req.id = self._next_id
        self._next_id += 1
        req.state = RequestState.QUEUED
        self._waiting.append(req)
        self.stats["submitted"] += 1
        return req

    # -- slots -------------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    def bind(self, slot: int, req: Request) -> None:
        if self._slots[slot] is not None:
            raise RuntimeError(f"slot {slot} already bound to {self._slots[slot]}")
        self._slots[slot] = req
        req.slot = slot
        req.state = RequestState.DECODING

    def release(self, slot: int) -> Request:
        req = self._slots[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} is not bound")
        self._slots[slot] = None
        req.slot = None
        self.stats["finished"] += 1
        return req

    def bound(self, slot: int) -> Optional[Request]:
        return self._slots[slot]

    def next_waiting(self) -> Optional[Request]:
        """Pop the FCFS head of the waiting queue (None when empty)."""
        return self._waiting.popleft() if self._waiting else None

    # -- introspection -------------------------------------------------------

    @property
    def waiting_depth(self) -> int:
        return len(self._waiting)

    @property
    def active_slots(self) -> int:
        return sum(1 for r in self._slots if r is not None)

    @property
    def idle(self) -> bool:
        return self.active_slots == 0 and not self._waiting

    def active_requests(self) -> List[Request]:
        return [r for r in self._slots if r is not None]
