"""Serving engine: batched prefill + decode with the butterfly sampler.

Token sampling from a vocab-sized categorical per sequence is *exactly* the
paper's setting (K = vocab, one distribution per batch row, each table used
once) — the decode step's sampler is the paper's technique as a first-class
serving feature.  Since the distribution-object redesign the engine builds
a :class:`repro.sampling.SamplerPlan` in ``make_decode_step`` /
``make_serve_step`` / ``make_prefill_step`` — ``ModelConfig.sampler_spec``
(a ``SamplerSpec``) is resolved through ``repro.autotune`` **once per
(B, vocab) workload at plan time**, not re-dispatched from strings on
every step; the jitted step then draws through the plan's compiled path.

Multi-draw decode (``make_decode_step(..., num_samples=n)``) samples n
candidate tokens per sequence from one built distribution per step; for a
kernel-variant plan all B*n walks run in ONE tiled pass-B launch.

Sharded decode (``make_decode_step(..., mesh=mesh)``) row-shards the
sequences over the mesh's data axes and samples per shard through the
shard_map'd kernel path with counter RNG — no collectives on the draw
path, no per-draw key splitting, and tokens independent of the device
count for a fixed key (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sampling
from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models.params import init_params


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_new)
    steps: int
    prefill_len: int


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode sampling controls — a registered pytree whose
    leaves (``temperature``/``top_k``/``top_p``/``min_p``) are *traced*
    operands of the decode step: one compiled executable serves any mix
    of values, including per-row (B,) arrays for heterogeneous batches
    (request i gets its own top-p).

    ``temperature=None`` (the default) defers to the engine's
    ``temperature`` argument; a numeric/array value overrides it and must
    be > 0 (greedy decode is the engine's ``temperature=0``, decided at
    trace time).  ``top_k=0`` / ``top_p=1.0`` / ``min_p=0.0`` disable the
    respective truncation — per row, when arrays."""

    temperature: object = None
    top_k: object = 0
    top_p: object = 1.0
    min_p: object = 0.0

    def transforms(self):
        """The truncation chain (canonical top-k -> top-p -> min-p; the
        temperature is threaded separately so greedy stays decidable)."""
        from repro.sampling import transforms as _tr

        return _tr.chain(top_k=self.top_k, top_p=self.top_p, min_p=self.min_p)


jax.tree_util.register_pytree_node(
    SamplingParams,
    lambda sp: ((sp.temperature, sp.top_k, sp.top_p, sp.min_p), None),
    lambda aux, ch: SamplingParams(*ch),
)

def _sp_sig(sp: Optional["SamplingParams"]) -> str:
    """The transforms signature a SamplingParams default actually runs
    (statically-disabled stages are dropped by ``transforms.chain``), for
    the plan memo key and autotune v4 bucket."""
    if sp is None:
        return ""
    from repro.sampling import transforms as _tr

    return _tr.signature(sp.transforms())


def default_sampling_params(cfg: ModelConfig) -> Optional[SamplingParams]:
    """The config's model-card decode defaults lifted into
    ``SamplingParams`` — ``None`` when the spec doesn't truncate (plain
    temperature decode keeps the untruncated fast path)."""
    spec = cfg.sampler_spec
    if not spec.truncates:
        return None
    return SamplingParams(
        top_k=spec.top_k, top_p=spec.top_p, min_p=spec.min_p
    )


def _logits_plan(cfg: ModelConfig, B: int, V: int, dtype_name: str,
                 draws: int = 1, mesh=None, transforms: str = ""):
    """The config's sampler spec, planned for a (B, V) logits workload.

    ``sampling.plan`` memoizes process-wide, so this resolves autotune on
    the first (shape, dtype) sighting and is a dictionary hit after —
    whether called eagerly (known batch size) or at trace time.
    ``draws`` is the per-distribution reuse hint (multi-draw decode).
    ``mesh`` makes the plan sharded: sequences row-shard over the mesh's
    data axes and the sampler runs per shard (the topology is part of the
    plan memo key, so one engine can serve several meshes).
    ``transforms`` is the truncation-chain signature for truncated decode
    (joins the autotune v4 bucket; parameter values stay out)."""
    spec = cfg.sampler_spec
    return sampling.plan(
        (B, V), method=spec.method, W=spec.W or None, dtype=dtype_name,
        draws=max(spec.draws, draws), has_key=True, mesh=mesh,
        transforms=transforms,
    )


# sentinel distinguishing "``sampling`` not given -> factory defaults"
# from an explicit ``sampling=None`` -> plain untruncated decode
_SP_UNSET = object()


def _chain_for(sp: "SamplingParams", sig: str):
    """The truncation chain matching a *static* signature, carrying THIS
    call's (possibly traced) parameter leaves.  Rebuilding the chain from
    traced leaves via ``sp.transforms()`` would resurrect statically
    dropped stages (a tracer is never "statically disabled"); selecting
    stages by the signature keeps the executable, the plan's memo key,
    and the autotune bucket mutually consistent."""
    from repro.sampling import transforms as _tr

    out = []
    if "k" in sig:
        out.append(_tr.TopK(sp.top_k))
    if "p" in sig:
        out.append(_tr.TopP(sp.top_p))
    if "m" in sig:
        out.append(_tr.MinP(sp.min_p))
    return tuple(out)


def make_decode_step(
    model: Model,
    temperature: float = 1.0,
    batch_size: Optional[int] = None,
    num_samples: int = 1,
    mesh=None,
    sampling_params: Optional[SamplingParams] = None,
):
    """Jitted decode step: (params, caches, token, pos, key[, sampling])
    -> (next_token(s), logits, caches).

    When ``batch_size`` is known up front the sampler plan is built (and
    autotune resolved) eagerly, before the first trace; otherwise planning
    happens at trace time on first use and is memoized after.

    ``num_samples > 1`` draws that many candidate tokens per sequence from
    ONE built distribution (speculative/best-of-n decode): the step
    returns (B, num_samples) candidates, the plan is resolved with the
    reuse hint ``draws=num_samples``, and a kernel-variant plan walks all
    B*num_samples draws in a single tiled pass-B launch (the ``rows``
    indirection in the kernel) instead of rebuilding tables per draw.

    ``mesh`` makes the decode step *sharded*: sequences (and their
    logits) row-shard over the mesh's data axes, and the sampler runs as
    a shard_map of the same tiled kernels with counter RNG — zero
    collectives on the draw path, tokens bit-identical for any device
    count at a fixed key (DESIGN.md §5).  Requires ``batch_size`` (or the
    first traced batch) divisible by the data-shard count.

    The returned step ALWAYS accepts an optional trailing ``sampling``
    argument, whatever the factory arguments were: omitted, it falls back
    to ``sampling_params`` (else the model config's
    ``SamplerSpec.top_k/top_p/min_p`` model-card defaults, else plain
    untruncated decode); an explicit ``sampling=None`` forces the plain
    untruncated path for that call; a :class:`SamplingParams` runs
    truncated decode with *that call's* parameters — its leaves are
    traced, so per-request (even per-row ``(B,)`` heterogeneous) values
    reuse one compiled executable.  The truncation chain's *shape* (which
    stages exist) is resolved per call from the concrete parameters and
    threaded statically, so a call can never silently inherit an earlier
    call's (or the factory default's) stage set — only calls that change
    which stages are statically enabled retrace.  Execution is
    butterfly-native (fused threshold pass, no vocab sort — see
    ``repro.sampling.transforms``)."""
    cfg = model.cfg
    sp0 = sampling_params if sampling_params is not None else (
        default_sampling_params(cfg)
    )
    if batch_size is not None:
        _logits_plan(cfg, batch_size, cfg.padded_vocab, "float32",
                     draws=num_samples, mesh=mesh, transforms=_sp_sig(sp0))

    def _shape(nxt, logits, caches):
        if num_samples == 1:
            return nxt[:, None].astype(jnp.int32), logits, caches
        return nxt.T.astype(jnp.int32), logits, caches  # (B, num_samples)

    @jax.jit
    def plain_step(params, caches, token, pos, key):
        logits, caches = model.decode(params, caches, token, pos)
        p = _logits_plan(
            cfg, logits.shape[0], logits.shape[1], str(logits.dtype),
            draws=num_samples, mesh=mesh,
        )
        nxt = p.sample_logits(
            logits, key, temperature=temperature, num_samples=num_samples
        )
        return _shape(nxt, logits, caches)

    @functools.partial(jax.jit, static_argnames=("sig",))
    def trunc_step(params, caches, token, pos, key, sampling, sig):
        logits, caches = model.decode(params, caches, token, pos)
        p = _logits_plan(
            cfg, logits.shape[0], logits.shape[1], str(logits.dtype),
            draws=num_samples, mesh=mesh, transforms=sig,
        )
        temp = (
            sampling.temperature if sampling.temperature is not None
            else temperature
        )
        tr = _chain_for(sampling, sig)
        nxt = p.sample_logits(
            logits, key, temperature=temp, num_samples=num_samples,
            transforms=tr if tr else None,
        )
        return _shape(nxt, logits, caches)

    def step(params, caches, token, pos, key, sampling=_SP_UNSET):
        sp = sp0 if sampling is _SP_UNSET else sampling
        if sp is None:
            return plain_step(params, caches, token, pos, key)
        return trunc_step(params, caches, token, pos, key, sp, sig=_sp_sig(sp))

    # the zero-retrace gate reads these (tests, serve bench)
    step.plain_cache_size = plain_step._cache_size
    step.trunc_cache_size = trunc_step._cache_size
    return step


# cache leaves with a (L, B, S, ...) sequence axis (axis 2)
_SEQ_CACHE_LEAVES = frozenset({"k", "v", "c_kv", "k_pe", "self_k", "self_v"})


def _pad_caches_to(caches, target_len: int):
    """Grow attention caches (L, B, S, ...) along the seq axis to target.

    Caches already at (or beyond) ``target_len`` are returned *as-is* —
    the identical pytree, no per-leaf dispatch — so callers can re-pad
    unconditionally (repeated ``generate`` over one cache, the serve
    engine's admission path) without paying a device round-trip for a
    no-op."""
    def _names(path):
        return {getattr(k, "key", None) for k in path}

    if all(
        leaf.shape[2] >= target_len
        for path, leaf in jax.tree_util.tree_leaves_with_path(caches)
        if _names(path) & _SEQ_CACHE_LEAVES
    ):
        return caches

    def pad(path, leaf):
        if _names(path) & _SEQ_CACHE_LEAVES:
            cur = leaf.shape[2]
            if cur < target_len:
                pads = [(0, 0), (0, 0), (0, target_len - cur)] + [(0, 0)] * (leaf.ndim - 3)
                return jnp.pad(leaf, pads)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, caches)


def generate(
    model: Model,
    params,
    batch: Dict,
    max_new_tokens: int = 16,
    temperature: float = 1.0,
    key: Optional[jax.Array] = None,
    eos_id: Optional[int] = None,
    mesh=None,
) -> GenerationResult:
    """Prefill the prompt batch, then decode ``max_new_tokens`` greedily or
    by sampling.  Python loop around a jitted step (engine-style).

    ``mesh`` shards the decode sampler like :func:`make_decode_step`:
    sequences row-shard over the mesh's data axes and the draw runs
    through the shard_map'd counter-RNG path (the launch/serve (dp, tp)
    wiring).  The prompt batch must divide by the data-shard count."""
    cfg = model.cfg
    key = key if key is not None else jax.random.PRNGKey(0)
    last_logits, caches = model.prefill(params, batch)
    toks = batch["tgt_tokens"] if "tgt_tokens" in batch else batch["tokens"]
    B, S = toks.shape
    prefix = cfg.meta_tokens + (
        batch["frontend_embeds"].shape[1] if "frontend_embeds" in batch else 0
    )
    prefill_len = S + prefix
    caches = _pad_caches_to(caches, prefill_len + max_new_tokens)

    step_fn = make_decode_step(model, temperature, batch_size=B, mesh=mesh)
    k0, key = jax.random.split(key)
    sp0 = default_sampling_params(cfg)  # model-card truncation, if any
    first_plan = _logits_plan(
        cfg, last_logits.shape[0], last_logits.shape[1],
        str(last_logits.dtype), mesh=mesh, transforms=_sp_sig(sp0),
    )
    first = first_plan.sample_logits(
        last_logits, k0, temperature=temperature,
        transforms=sp0.transforms() if sp0 else None,
    )[:, None].astype(jnp.int32)

    out = [np.asarray(first)]
    token = first
    done = np.zeros((B,), bool)
    for t in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        token, _, caches = step_fn(
            params, caches, token, jnp.int32(prefill_len + t), sub
        )
        arr = np.asarray(token)
        if eos_id is not None:
            done |= (arr[:, 0] == eos_id)
            if done.all():
                out.append(arr)
                break
        out.append(arr)
    tokens = np.concatenate(out, axis=1)
    return GenerationResult(tokens=tokens, steps=tokens.shape[1], prefill_len=prefill_len)


def make_serve_step(
    model: Model, temperature: float = 1.0, batch_size: Optional[int] = None,
    mesh=None, sampling_params: Optional[SamplingParams] = None,
):
    """The dry-run target: one fused decode+sample step as a pure function
    (params, caches, token, pos, key) -> (next_token, caches).
    ``mesh`` shards the sampler like :func:`make_decode_step`;
    ``sampling_params`` (explicit only — the dry-run contract keeps the
    5-argument signature, so config defaults are not auto-applied here)
    bakes a truncation chain into the step."""
    cfg = model.cfg
    sig = _sp_sig(sampling_params)
    if batch_size is not None:
        _logits_plan(cfg, batch_size, cfg.padded_vocab, "float32", mesh=mesh,
                     transforms=sig)

    def serve_step(params, caches, token, pos, key):
        logits, caches = model.decode(params, caches, token, pos)
        p = _logits_plan(cfg, logits.shape[0], logits.shape[1],
                         str(logits.dtype), mesh=mesh, transforms=sig)
        if sampling_params is None:
            nxt = p.sample_logits(logits, key, temperature=temperature)
        else:
            temp = (
                sampling_params.temperature
                if sampling_params.temperature is not None else temperature
            )
            nxt = p.sample_logits(
                logits, key, temperature=temp,
                transforms=sampling_params.transforms(),
            )
        return nxt.astype(jnp.int32), caches

    return serve_step


def make_prefill_step(
    model: Model, temperature: float = 1.0, batch_size: Optional[int] = None
):
    """Dry-run prefill target: (params, batch, key) -> (first_token, caches)."""
    cfg = model.cfg
    if batch_size is not None:
        _logits_plan(cfg, batch_size, cfg.padded_vocab, "float32")

    def prefill_step(params, batch, key):
        last_logits, caches = model.prefill(params, batch)
        p = _logits_plan(
            cfg, last_logits.shape[0], last_logits.shape[1], str(last_logits.dtype)
        )
        nxt = p.sample_logits(last_logits, key, temperature=temperature)
        return nxt.astype(jnp.int32), caches

    return prefill_step
